"""TMFG construction: JAX vs numpy oracles + structural invariants."""

import numpy as np
import pytest

import jax

from conftest import clustered_similarity, random_symmetric
from repro.core import tmfg_ref as R
from repro.core.tmfg import build_tmfg

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


def _np(res):
    return jax.tree.map(np.asarray, res)


def check_invariants(res, n, S=None):
    """The paper's structural invariants (DESIGN.md §1)."""
    assert res.edges.shape == (3 * n - 6, 2)
    assert res.faces.shape == (2 * n - 4, 3)
    assert res.bubble_verts.shape == (n - 3, 4)
    # no duplicate / self edges
    e = np.sort(np.asarray(res.edges), axis=1)
    assert (e[:, 0] != e[:, 1]).all()
    assert len(set(map(tuple, e))) == 3 * n - 6
    # every vertex inserted exactly once
    assert sorted(np.asarray(res.insert_order).tolist()) == list(range(n))
    # bubble tree: parents precede children, root is bubble 0
    bp = np.asarray(res.bubble_parent)
    assert bp[0] == -1
    if n > 4:
        assert (bp[1:] >= 0).all() and (bp[1:] < np.arange(1, n - 3)).all()
    # every non-root bubble's separating triangle is a subset of its parent
    bv = np.asarray(res.bubble_verts)
    bt = np.asarray(res.bubble_tri)
    for b in range(1, n - 3):
        assert set(bt[b]) <= set(bv[bp[b]]), f"bubble {b} triangle not in parent"
        assert set(bt[b]) <= set(bv[b])
    # edge sum consistent
    if S is not None:
        s = sum(S[a, b] for a, b in e)
        assert abs(s - float(res.edge_sum)) < 1e-3 * n


@pytest.mark.parametrize("n", [8, 40, 90])
@pytest.mark.parametrize("method,ref_fn", [
    ("corr", R.tmfg_corr),
    ("lazy", R.tmfg_lazy),
])
def test_jax_matches_oracle(n, method, ref_fn):
    S, _, _ = clustered_similarity(n, seed=n)
    ref = ref_fn(S)
    got = _np(build_tmfg(S, method=method))
    assert (ref.insert_order == got.insert_order).all()
    np.testing.assert_allclose(ref.edge_sum, got.edge_sum, rtol=1e-4)
    assert (np.sort(ref.edges, 1) == np.sort(got.edges, 1)).all()
    assert (ref.bubble_parent == got.bubble_parent).all()
    check_invariants(got, n, S)


@pytest.mark.parametrize("prefix", [1, 7, 25])
def test_orig_matches_oracle(prefix):
    n = 60
    S, _, _ = clustered_similarity(n, seed=17)
    ref = R.tmfg_orig(S, prefix=prefix)
    got = _np(build_tmfg(S, method="orig", prefix=prefix))
    assert (ref.insert_order == got.insert_order).all()
    np.testing.assert_allclose(ref.edge_sum, got.edge_sum, rtol=1e-4)
    check_invariants(got, n, S)


def test_orig_prefix1_equals_exact_serial():
    S, _, _ = clustered_similarity(50, seed=3)
    assert (R.tmfg_orig(S, 1).insert_order == R.tmfg_exact(S).insert_order).all()


def test_topk_lookup_equivalent():
    """The top-K candidate table must not change the construction."""
    n = 80
    S, _, _ = clustered_similarity(n, seed=9)
    base = _np(build_tmfg(S, method="lazy", topk=0))
    for K in (4, 16, 128):
        tk = _np(build_tmfg(S, method="lazy", topk=K))
        assert (base.insert_order == tk.insert_order).all(), f"topk={K}"


def test_edge_sum_quality_ordering():
    """Paper §5.2: corr/lazy edge sums within ~1% of exact; large prefixes
    are strictly worse."""
    n = 150
    S, _, _ = clustered_similarity(n, k=5, seed=21)
    exact = R.tmfg_exact(S).edge_sum
    corr = float(build_tmfg(S, method="corr").edge_sum)
    lazy = float(build_tmfg(S, method="lazy").edge_sum)
    p200 = float(build_tmfg(S, method="orig", prefix=200).edge_sum)
    assert corr >= 0.97 * exact
    assert lazy >= 0.97 * exact
    assert abs(corr - lazy) <= 0.01 * abs(exact)
    assert p200 < lazy  # large prefix degrades quality (paper fig. 7)


def test_lazy_pops_bounded():
    """Lazy revalidation overhead: pops = n-4 inserts + few stale refreshes."""
    n = 120
    S, _, _ = clustered_similarity(n, seed=5)
    res = _np(build_tmfg(S, method="lazy"))
    inserts = n - 4
    assert res.pops >= inserts
    assert res.pops <= 12 * inserts, f"too many stale pops: {res.pops}"


if HAVE_HYP:

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=5, max_value=40), st.integers(0, 10_000))
    def test_property_invariants_random(n, seed):
        """Hypothesis: invariants hold for arbitrary symmetric inputs."""
        S = random_symmetric(n, seed)
        res = _np(build_tmfg(S, method="lazy"))
        check_invariants(res, n, S)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=6, max_value=30), st.integers(0, 10_000))
    def test_property_lazy_matches_ref(n, seed):
        S = random_symmetric(n, seed)
        ref = R.tmfg_lazy(S)
        got = _np(build_tmfg(S, method="lazy"))
        # ties are possible with arbitrary data; compare edge sums not order
        assert float(got.edge_sum) >= float(ref.edge_sum) - 1e-3
