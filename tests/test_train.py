"""Training substrate: optimizer, train step, checkpointing, compression,
elastic logic."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.dist import compression
from repro.models.registry import build_model
from repro.train import checkpoint, optimizer
from repro.train.elastic import HeartbeatRegistry, StragglerMonitor
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-8b").reduced(n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16))),
    }
    return cfg, model, params, batch


def test_loss_decreases(setup):
    cfg, model, params, batch = setup
    run_cfg = RunConfig(lr=1e-3, warmup_steps=1, total_steps=50,
                        microbatches=1)
    step = jax.jit(make_train_step(model, run_cfg,
                                   loss_kwargs=dict(q_chunk=8, kv_chunk=8)))
    opt = optimizer.init(params)
    p = params
    losses = []
    for _ in range(8):
        p, opt, m = step(p, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatching_matches_full_batch(setup):
    """Grad accumulation over M microbatches == one full-batch step."""
    cfg, model, params, batch = setup
    kw = dict(loss_kwargs=dict(q_chunk=8, kv_chunk=8))
    rc1 = RunConfig(lr=1e-3, warmup_steps=1, microbatches=1)
    rc4 = RunConfig(lr=1e-3, warmup_steps=1, microbatches=4)
    s1 = jax.jit(make_train_step(model, rc1, **kw))
    s4 = jax.jit(make_train_step(model, rc4, **kw))
    opt = optimizer.init(params)
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


def test_checkpoint_roundtrip(tmp_path, setup):
    _, model, params, _ = setup
    opt = optimizer.init(params)
    path = str(tmp_path / "ckpt")
    checkpoint.save((params, opt), path, step=7, extras={"note": "x"})
    (p2, o2), step, extras = checkpoint.restore((params, opt), path)
    assert step == 7 and extras == {"note": "x"}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path, setup):
    """A half-written checkpoint must never shadow a good one."""
    _, model, params, _ = setup
    path = str(tmp_path / "ckpt")
    checkpoint.save(params, path, step=1)
    # simulate a crashed save: a stale .tmp dir left behind
    os.makedirs(os.path.join(path, ".tmp-step_000000002", "arrays"),
                exist_ok=True)
    assert checkpoint.latest_step(path) == 1
    p2, step, _ = checkpoint.restore(params, path)
    assert step == 1


def test_checkpoint_keep_last_k(tmp_path, setup):
    _, _, params, _ = setup
    path = str(tmp_path / "ckpt")
    for s in range(5):
        checkpoint.save(params, path, step=s, keep=2)
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    assert len(steps) == 2
    assert checkpoint.latest_step(path) == 4


def test_async_checkpointer(tmp_path, setup):
    _, _, params, _ = setup
    path = str(tmp_path / "ckpt")
    ck = checkpoint.AsyncCheckpointer(path, keep=2)
    ck.save(params, 3)
    ck.wait()
    assert checkpoint.latest_step(path) == 3


def test_train_resume_bitwise(tmp_path):
    """10 straight steps == 5 steps + checkpoint + restore + 5 steps."""
    from repro.launch import train as T

    cfg = get_config("xlstm-125m").reduced(n_layers=2)
    model = build_model(cfg)
    run_cfg = RunConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step_fn = jax.jit(make_train_step(model, run_cfg))
    params = model.init(jax.random.PRNGKey(0))
    opt = optimizer.init(params)

    p, o = params, opt
    for s in range(10):
        p, o, _ = step_fn(p, o, T.synthetic_batch(cfg, s, 2, 32))
    ref = p

    p, o = params, opt
    for s in range(5):
        p, o, _ = step_fn(p, o, T.synthetic_batch(cfg, s, 2, 32))
    path = str(tmp_path / "ck")
    checkpoint.save((p, o), path, step=5)
    (p, o), s0, _ = checkpoint.restore((p, o), path)
    for s in range(s0, 10):
        p, o, _ = step_fn(p, o, T.synthetic_batch(cfg, s, 2, 32))

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(333, 57)).astype(np.float32))
    out = compression.quantize_dequantize(g)
    err = np.abs(np.asarray(out - g))
    scale = np.abs(np.asarray(g)).max() / 127
    assert err.max() <= scale * 1.01


def test_error_feedback_accumulates():
    """With a CONSTANT gradient, error feedback makes the long-run mean of
    the compressed gradients converge to the true gradient."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 1e-3)}
    ef = compression.ef_init(g)
    total = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        comp, ef = compression.compress_with_feedback(g, ef)
        total = total + comp["w"]
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------

def test_straggler_detection():
    m = StragglerMonitor(window=8, threshold=4.0)
    for step in range(8):
        for h in range(8):
            m.record(h, 1.0 + 0.01 * h + (5.0 if h == 3 else 0.0))
    assert m.stragglers() == [3]
    w = m.rebalance_weights(8)
    assert w[3] < min(w[h] for h in range(8) if h != 3)


def test_heartbeats():
    r = HeartbeatRegistry(timeout=10.0)
    r.beat(0, now=0.0)
    r.beat(1, now=0.0)
    r.beat(0, now=20.0)
    assert r.dead_hosts(now=21.0) == [1]


def test_supervisor_simulation():
    from repro.launch.cluster import simulate_failure_recovery

    plans = simulate_failure_recovery(n_hosts=16, chips_per_host=32,
                                      kill=(3,), straggle=(7,))
    actions = [p["action"] for p in plans]
    assert "remesh" in actions
    remesh = [p for p in plans if p["action"] == "remesh"]
    # both the dead host and the straggler eventually evicted
    evicted = {h for p in remesh for h in p["evicted"]}
    assert 3 in evicted and 7 in evicted
    # final mesh keeps the model axis and is a valid grid
    mesh = remesh[-1]["mesh"]
    assert mesh[2] == 16 and all(m >= 1 for m in mesh)


def test_largest_mesh():
    from repro.launch.cluster import largest_mesh

    assert largest_mesh(512) == (2, 16, 16)
    assert largest_mesh(512 - 32) == (1, 30, 16)
    assert largest_mesh(256) == (1, 16, 16)
