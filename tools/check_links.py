#!/usr/bin/env python
"""Link-check every Markdown file in the repo (no network needed).

Verifies that each relative `[text](target)` link in `*.md` points at a
file or directory that exists (anchors `#...` are stripped; absolute
`http(s)://` and `mailto:` links are skipped — CI must not depend on
external availability).  Exits nonzero listing every broken link.

Run from anywhere:  python tools/check_links.py [root]
Also imported by tests/test_docs.py so the same rule is a tier-1 test.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def broken_links(root: Path) -> list:
    """[(md file, target), ...] for every relative link that dangles."""
    bad = []
    for md in sorted(root.rglob("*.md")):
        if any(part.startswith(".") for part in md.relative_to(root).parts):
            continue  # .git, .github READMEs etc. are not repo docs
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                bad.append((str(md.relative_to(root)), target))
    return bad


def main(argv=None) -> int:
    root = Path(argv[0]) if argv else Path(__file__).resolve().parent.parent
    bad = broken_links(root)
    for md, target in bad:
        print(f"BROKEN {md}: ({target})")
    print(f"# checked *.md under {root}: {len(bad)} broken link(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
